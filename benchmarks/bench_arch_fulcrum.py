"""Deliverable tie-in: Fulcrum scheduling the 10 ASSIGNED architectures.

Each architecture is mapped onto an edge workload profile (FLOPs/bytes-
derived, core.device_model.workload_from_model_config); GMD plans standalone
inference under an edge-realistic budget, and a concurrent pair (train the
small SSM while serving each arch) exercises managed interleaving on the
non-dense families where the paper's technique matters most."""
from __future__ import annotations

from repro.configs import ARCH_IDS, get_config
from repro.core import problem as P
from repro.core.device_model import Profiler, workload_from_model_config
from repro.core.gmd import ConcurrentProfiler, GMDConcurrent, GMDInfer

from benchmarks.common import DEV, SPACE, row


def run(full: bool = False) -> list[str]:
    rows = []
    # edge-scale check: schedule each arch's inference (token budget scaled
    # down to edge-feasible sequel lengths)
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        w = workload_from_model_config(cfg, "infer", tokens_per_sample=128)
        # budget scales with model size: tiny archs get tight budgets
        lat = 2.0 if cfg.param_count() < 5e9 else 30.0
        rate = 4.0 if cfg.param_count() < 5e9 else 0.2
        prof = Profiler(DEV, w)
        sol = GMDInfer(prof, SPACE).solve(P.InferProblem(40.0, lat, rate))
        if sol is None:
            rows.append(row(f"arch_fulcrum/{arch}/infer", "unsolved",
                            f"params={cfg.param_count()/1e9:.1f}B"))
        else:
            rows.append(row(f"arch_fulcrum/{arch}/infer_latency_ms",
                            sol.time * 1e3,
                            f"pm={sol.pm};bs={sol.bs};power={sol.power:.1f}W;"
                            f"modes={prof.num_runs}"))

    # concurrent: train mamba2-780m while serving zamba2/internvl2/musicgen
    w_tr = workload_from_model_config(get_config("mamba2-780m"), "train",
                                      tokens_per_sample=128)
    for arch in ("zamba2-1.2b", "internvl2-1b", "musicgen-medium"):
        w_in = workload_from_model_config(get_config(arch), "infer",
                                          tokens_per_sample=128)
        cp = ConcurrentProfiler(Profiler(DEV, w_tr), Profiler(DEV, w_in))
        sol = GMDConcurrent(cp, SPACE).solve(P.ConcurrentProblem(45.0, 4.0, 2.0))
        if sol is None:
            rows.append(row(f"arch_fulcrum/mamba2+{arch}/concurrent", "unsolved"))
        else:
            rows.append(row(f"arch_fulcrum/mamba2+{arch}/train_tput_mb_s",
                            sol.throughput,
                            f"pm={sol.pm};bs={sol.bs};tau={sol.tau_tr};"
                            f"lat={sol.time*1e3:.0f}ms"))
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
