"""Deliverable (g): roofline analysis from the dry-run's compiled artifacts.

Per (arch x shape x mesh):
  compute term    = HLO_FLOPs / (chips x 197 TFLOP/s bf16)
  memory term     = HLO_bytes / (chips x 819 GB/s)
  collective term = collective_bytes / (chips x 50 GB/s link)

FLOPs/bytes/collective-bytes come from the depth-extrapolated unrolled probes
(dryrun.json 'extrapolated' — XLA while-loop bodies are otherwise counted
once); they are per-device quantities of the partitioned module, so each term
divides by per-chip peaks directly (equivalent to global/chips x peak).
MODEL_FLOPS = 6*N_active*D (train) or 2*N_active*D (fwd) measures useful work;
ratio vs HLO flops exposes remat/capacity/padding waste.
"""
from __future__ import annotations

import json
from pathlib import Path

from repro.launch.mesh import HBM_BW, HBM_BYTES, ICI_BW, PEAK_FLOPS_BF16

DRYRUN_JSON = Path(__file__).parent / "results" / "dryrun.json"


def roofline_terms(rec: dict) -> dict:
    ex = rec.get("extrapolated", {})
    chips = rec["devices"]
    flops_dev = ex.get("flops")
    bytes_dev = ex.get("bytes accessed")
    coll_dev = ex.get("collective_bytes")
    out = {
        "compute_s": flops_dev / PEAK_FLOPS_BF16 if flops_dev else None,
        "memory_s": bytes_dev / HBM_BW if bytes_dev else None,
        "collective_s": coll_dev / ICI_BW if coll_dev else None,
    }
    terms = {k: v for k, v in out.items() if v is not None}
    out["dominant"] = max(terms, key=terms.get) if terms else "n/a"
    mf = rec.get("model_flops", 0.0) / chips        # useful flops per device
    out["model_flops_dev"] = mf
    out["useful_ratio"] = (mf / flops_dev) if flops_dev else None
    mem = rec.get("full", {}).get("memory", {})
    args = mem.get("argument_size_in_bytes")
    temp = mem.get("temp_size_in_bytes")
    out["hbm_args_gb"] = args / 2**30 if args else None
    out["hbm_temp_gb"] = temp / 2**30 if temp else None
    out["fits_hbm"] = (args is not None and temp is not None
                       and args + temp <= HBM_BYTES)
    return out


def run(full: bool = False, path: Path = DRYRUN_JSON) -> list[str]:
    if not path.exists():
        return ["roofline/missing_dryrun_json,1,run repro.launch.dryrun first"]
    data = json.loads(path.read_text())
    rows = []
    n_ok = 0
    for key, rec in sorted(data.items()):
        if not rec.get("ok"):
            rows.append(f"roofline/{key}/FAILED,1,{rec.get('error', '')[:80]}")
            continue
        n_ok += 1
        t = roofline_terms(rec)
        fmt = lambda v: f"{v:.3e}" if isinstance(v, float) else v
        rows.append(
            f"roofline/{key},{fmt(t['compute_s'])},"
            f"mem={fmt(t['memory_s'])};coll={fmt(t['collective_s'])};"
            f"dominant={t['dominant']};useful={t['useful_ratio'] and round(t['useful_ratio'], 3)};"
            f"args_gb={t['hbm_args_gb'] and round(t['hbm_args_gb'], 2)};"
            f"temp_gb={t['hbm_temp_gb'] and round(t['hbm_temp_gb'], 2)}")
    rows.append(f"roofline/pairs_ok,{n_ok},of {len(data)}")
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
